"""Diff two benchmark-artifact directories (nightly perf trajectory).

    python benchmarks/diff_bench.py BASELINE_DIR CURRENT_DIR [--out diff.md]

Flattens every `*.json` in both directories to dotted numeric paths and
reports, per metric, the old value, new value and relative change; metrics
whose |relative change| exceeds the threshold are flagged.  Report-only by
design: nightly runs on shared CI runners are noisy, so the job uploads the
diff for humans instead of failing the build (tier-1 correctness gating
lives in the test suite, not here).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict


def _flatten(obj, prefix="") -> Dict[str, float]:
    out: Dict[str, float] = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(_flatten(v, f"{prefix}.{k}" if prefix else str(k)))
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            out.update(_flatten(v, f"{prefix}[{i}]"))
    elif isinstance(obj, bool):
        out[prefix] = float(obj)
    elif isinstance(obj, (int, float)):
        out[prefix] = float(obj)
    return out


def _load_dir(path: str) -> Dict[str, Dict[str, float]]:
    out = {}
    if not os.path.isdir(path):
        return out
    for name in sorted(os.listdir(path)):
        if name.endswith(".json"):
            try:
                with open(os.path.join(path, name)) as f:
                    out[name] = _flatten(json.load(f))
            except (json.JSONDecodeError, OSError) as e:
                print(f"warning: skipping {name}: {e}", file=sys.stderr)
    return out


def diff(baseline_dir: str, current_dir: str, threshold: float = 0.10) -> str:
    base = _load_dir(baseline_dir)
    cur = _load_dir(current_dir)
    lines = ["# Bench diff", "",
             f"baseline: `{baseline_dir}`  current: `{current_dir}`", ""]
    if not base:
        lines.append("_no baseline artifacts (first nightly run?) - "
                     "nothing to diff_")
    for name in sorted(set(base) | set(cur)):
        if name not in base:
            lines.append(f"## {name}: NEW (no baseline)")
            continue
        if name not in cur:
            lines.append(f"## {name}: MISSING from current run")
            continue
        b, c = base[name], cur[name]
        flagged, changed = [], 0
        for key in sorted(set(b) | set(c)):
            if key not in b or key not in c:
                flagged.append(f"- `{key}`: "
                               f"{'added' if key not in b else 'removed'}")
                continue
            if b[key] == c[key]:
                continue
            changed += 1
            rel = ((c[key] - b[key]) / abs(b[key])) if b[key] else float("inf")
            if abs(rel) >= threshold:
                flagged.append(f"- `{key}`: {b[key]:g} -> {c[key]:g} "
                               f"({rel:+.1%})")
        lines.append(f"## {name}: {changed} metric(s) changed, "
                     f"{len(flagged)} flagged (>= {threshold:.0%})")
        lines.extend(flagged)
    return "\n".join(lines) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline_dir")
    ap.add_argument("current_dir")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative change that gets flagged (default 10%%)")
    ap.add_argument("--out", default=None, help="also write the report here")
    args = ap.parse_args()
    report = diff(args.baseline_dir, args.current_dir, args.threshold)
    print(report)
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "w") as f:
            f.write(report)


if __name__ == "__main__":
    main()

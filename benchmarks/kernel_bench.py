"""Pallas kernel microbenchmarks.

On this CPU container kernels execute in interpret mode (Python per grid
step), so wall times here measure the *oracle* jnp path as the meaningful
number and the interpret path only for correctness parity; the TPU numbers
come from the roofline analysis (EXPERIMENTS.md).  derived = model GB
touched per call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import (csv_row, mc_solutions, mc_solutions_recursive,
                               save_json, timed, _mc_problem)
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.kernels import ops, ref

G0 = 100e-6


def mc_path_bench(out, n_sims: int = 40):
    """Batched level-scheduled Monte-Carlo path vs the per-seed recursive
    tree walk it replaced (paper Fig. 8 two-stage configs).

    The win comes from batching the many small leaf arrays across seeds
    (e.g. 16x 64x64 for the 256^2 two-stage solve); at large leaf sizes a
    single LU already saturates the core and the two paths converge.
    """
    for n in (64, 256):
        stages = 2
        cfg = AnalogConfig(array_size=n // 4,
                           nonideal=NonidealConfig(sigma=0.05))
        a, b, _, keys = _mc_problem("wishart", n, n_sims, seed=0)
        batched = functools.partial(mc_solutions, solver="blockamc",
                                    stages=stages)
        recursive = jax.jit(functools.partial(
            mc_solutions_recursive, solver="blockamc", stages=stages,
            cfg=cfg))
        us_new = timed(lambda: batched(a, b, keys, cfg))
        us_old = timed(lambda: recursive(a, b, keys))
        speedup = us_old / us_new
        csv_row(f"mc_batched_n{n}_s{stages}", us_new,
                f"recursive={us_old:.1f}us;speedup={speedup:.2f}x")
        out[f"mc_n{n}"] = {"batched_us": us_new, "recursive_us": us_old,
                           "speedup": speedup}


def main():
    out = {}
    mc_path_bench(out)
    for b, r, c in ((256, 512, 512), (512, 1024, 1024)):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        v = jax.random.uniform(k1, (b, c), minval=-1, maxval=1)
        gp = jax.random.uniform(k2, (r, c), maxval=G0)
        gn = jax.random.uniform(k3, (r, c), maxval=G0)
        fn = jax.jit(lambda v, gp, gn: ref.crossbar_mvm_ref(
            v, gp, gn, g0=G0, dac_bits=8, adc_bits=8))
        us = timed(fn, v, gp, gn)
        gb = (v.size + gp.size + gn.size + b * r) * 4 / 1e9
        csv_row(f"crossbar_mvm_ref_{b}x{r}x{c}", us, f"GB={gb:.3f}")
        out[f"crossbar_{b}x{r}x{c}"] = us

    # Leading-dim batched entry point: one (L, R, C) shape-bucket stack of
    # the flat executor driven in a single call (oracle path timed; the
    # Pallas kernel is parity-checked in tests/test_kernels.py).
    for l, b, r, c in ((16, 64, 64, 64), (16, 128, 128, 128)):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
        v = jax.random.uniform(k1, (l, b, c), minval=-1, maxval=1)
        gp = jax.random.uniform(k2, (l, r, c), maxval=G0)
        gn = jax.random.uniform(k3, (l, r, c), maxval=G0)
        fn = jax.jit(jax.vmap(lambda vv, gpp, gnn: ref.crossbar_mvm_ref(
            vv, gpp, gnn, g0=G0, dac_bits=8, adc_bits=8)))
        us = timed(fn, v, gp, gn)
        gb = (v.size + gp.size + gn.size + l * b * r) * 4 / 1e9
        csv_row(f"crossbar_mvm_batched_ref_{l}x{b}x{r}x{c}", us,
                f"GB={gb:.3f}")
        out[f"crossbar_batched_{l}x{b}x{r}x{c}"] = us

    for n in (512, 1024):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
        a4 = jax.random.normal(k1, (n, n))
        a3 = jax.random.normal(k2, (n, n))
        w = jax.random.normal(k3, (n, n))
        fn = jax.jit(lambda a4, a3, w: ref.schur_update_ref(a4, a3, w))
        us = timed(fn, a4, a3, w)
        csv_row(f"schur_update_ref_{n}", us,
                f"GFLOP={2 * n ** 3 / 1e9:.2f}")
        out[f"schur_{n}"] = us
    save_json("kernel_bench", out)
    return out


if __name__ == "__main__":
    main()

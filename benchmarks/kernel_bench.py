"""Pallas kernel microbenchmarks.

On this CPU container kernels execute in interpret mode (Python per grid
step), so wall times here measure the *oracle* jnp path as the meaningful
number and the interpret path only for correctness parity; the TPU numbers
come from the roofline analysis (EXPERIMENTS.md).  derived = model GB
touched per call.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from benchmarks.common import (csv_row, mc_solutions, mc_solutions_recursive,
                               save_json, timed, _mc_problem)
from repro.core import blockamc
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.kernels import ops, ref

G0 = 100e-6

# CI smoke mode (run.py --smoke): smallest configs only, so the job finishes
# in well under a minute while still exercising every bench code path and
# emitting the kernel_bench.json perf-trajectory artifact.
SMOKE = False

# Multi-tenant bench tenant counts; None = per-mode default ((4,) smoke,
# (4, 16) full).  Overridable via run.py --bench-tenants.
TENANTS = None


def mc_path_bench(out, n_sims: int = 40):
    """Batched level-scheduled Monte-Carlo path vs the per-seed recursive
    tree walk it replaced (paper Fig. 8 two-stage configs).

    The win comes from batching the many small leaf arrays across seeds
    (e.g. 16x 64x64 for the 256^2 two-stage solve); at large leaf sizes a
    single LU already saturates the core and the two paths converge.
    """
    for n in ((64,) if SMOKE else (64, 256)):
        stages = 2
        cfg = AnalogConfig(array_size=n // 4,
                           nonideal=NonidealConfig(sigma=0.05))
        a, b, _, keys = _mc_problem("wishart", n, n_sims, seed=0)
        batched = functools.partial(mc_solutions, solver="blockamc",
                                    stages=stages)
        recursive = jax.jit(functools.partial(
            mc_solutions_recursive, solver="blockamc", stages=stages,
            cfg=cfg))
        us_new = timed(lambda: batched(a, b, keys, cfg))
        us_old = timed(lambda: recursive(a, b, keys))
        speedup = us_old / us_new
        csv_row(f"mc_batched_n{n}_s{stages}", us_new,
                f"recursive={us_old:.1f}us;speedup={speedup:.2f}x")
        out[f"mc_n{n}"] = {"batched_us": us_new, "recursive_us": us_old,
                           "speedup": speedup}


def program_once_bench(out, n: int = 256):
    """Program-once / solve-many amortization (paper Section III cost model).

    Fig. 8 two-stage config (n=256 -> 16 arrays of 64x64): one matrix is
    programmed and finalized once (`ProgrammedSolver`), then streams of
    right-hand sides are solved at marginal cost.  The baseline is per-call
    `execute_flat`, which re-pays the per-solve programming-time work
    (re-factorizes every INV bucket, re-derives every MVM tile operator)
    on every call - one call per arriving rhs, exactly what a serving loop
    without a programmed handle would do.  Reported per rhs count k:

      flat_percall_us   one execute_flat call with the (n, k) batch
      marginal_us       one ProgrammedSolver.solve_many with the same batch
      speedup_batch     like-for-like: flat_percall_us / marginal_us
      speedup_stream    serving: k per-rhs execute_flat calls vs one fused
                        solve_many - the headline amortization number

    Run for the paper's device-variation config and the full non-ideality
    config (+1 ohm wire model, where per-call operator re-derivation costs
    two n^2-matmuls per array side and finalization wins most).
    """
    rhs_counts = (1, 8) if SMOKE else (1, 8, 64)
    stages = 2
    for cold_start, (tag, ni) in enumerate((
            ("sigma", NonidealConfig(sigma=0.05)),
            ("sigma_wire", NonidealConfig(sigma=0.05, r_wire=1.0)))):
        cfg = AnalogConfig(array_size=n // 4, nonideal=ni)
        a, b, _, _ = _mc_problem("wishart", n, 1, seed=0)

        # time-to-first-solve = plan build + finalize + jit + first solve.
        # mode="reference" keeps this whole section the finalization-layer
        # bench (same executor for ttfs, marginal and speedups; the lazy
        # arena compile is never paid): the fused executor's own
        # programming and marginal costs are fused_bench's job.
        t0 = time.perf_counter()
        fplan = blockamc.build_flat_plan(a, jax.random.PRNGKey(7), cfg,
                                         stages=stages)
        solver = blockamc.ProgrammedSolver.from_plan(fplan, cfg,
                                                     mode="reference")
        jax.block_until_ready(solver.solve(b))
        ttfs_us = (time.perf_counter() - t0) * 1e6

        flat_fn = jax.jit(lambda fp, v: blockamc.execute_flat(fp, v, cfg))

        # Only the first config's ttfs is a true cold start; later ones
        # reuse jax compile/op caches warmed by earlier configs (same
        # shapes), so their programming cost reads low - flagged in the
        # artifact rather than paid for with per-config subprocesses.
        res = {"time_to_first_solve_us": ttfs_us,
               "cold_start": cold_start == 0, "rhs": {}}
        us_flat_1 = timed(flat_fn, fplan, b)
        for k in rhs_counts:
            bs = b if k == 1 else jax.random.normal(jax.random.PRNGKey(8),
                                                    (n, k))
            us_flat = us_flat_1 if k == 1 else timed(flat_fn, fplan, bs)
            # mode="reference" isolates the finalization layer's win over
            # per-call execute_flat; the arena executor's further speedup
            # on the same solver is fused_bench's job.
            us_marginal = timed(
                (lambda v: solver.solve(v, mode="reference")) if k == 1
                else (lambda v: solver.solve_many(v, mode="reference")), bs)
            res["rhs"][k] = {
                "flat_percall_us": us_flat,
                "marginal_us": us_marginal,
                "speedup_batch": us_flat / us_marginal,
                "speedup_stream": k * us_flat_1 / us_marginal,
            }
            csv_row(f"program_once_{tag}_n{n}_s{stages}_k{k}", us_marginal,
                    f"flat={us_flat:.1f}us;batch={us_flat / us_marginal:.2f}x;"
                    f"stream={k * us_flat_1 / us_marginal:.2f}x;"
                    f"ttfs={ttfs_us:.0f}us")
        # Headline number at the acceptance config: >= 8 streamed rhs.
        res["speedup"] = res["rhs"][8]["speedup_stream"]
        res["amortization"] = ttfs_us / res["rhs"][8]["marginal_us"]
        out[f"program_once_{tag}_n{n}"] = res


def fused_bench(out, n: int = 256):
    """Fused arena executor vs the finalized reference (ISSUE 4 acceptance).

    Fig. 8 two-stage config under the device-variation and wire-model
    regimes: marginal solve cost per rhs count for `execute_finalized`
    (mode="reference") vs the arena executor (mode="fused"), the
    `AnalogPreconditioner` apply inside preconditioned CG (the hybrid
    inner loop), and the interpret-mode whole-cascade megakernel smoke
    that CI runs on CPU.  The headline `speedup_marginal` is the largest
    streamed batch - the serving steady state the arena form targets.
    """
    stages = 2
    rhs_counts = (1, 8) if SMOKE else (1, 8, 64)
    for tag, ni in (("sigma", NonidealConfig(sigma=0.05)),
                    ("wire", NonidealConfig(sigma=0.05, r_wire=1.0))):
        cfg = AnalogConfig(array_size=n // 4, nonideal=ni)
        a, b, _, _ = _mc_problem("wishart", n, 1, seed=0)
        solver = blockamc.ProgrammedSolver.program(
            a, jax.random.PRNGKey(7), cfg, stages=stages)
        res = {"arena_size": solver.arena.arena_size,
               "peak_liveness": solver.arena.peak_liveness,
               "uniform_program": solver.arena.program is not None,
               "rhs": {}}
        for k in rhs_counts:
            bs = b if k == 1 else jax.random.normal(jax.random.PRNGKey(8),
                                                    (n, k))
            ref_fn = ((lambda v: solver.solve(v, mode="reference")) if k == 1
                      else (lambda v: solver.solve_many(v, mode="reference")))
            fus_fn = ((lambda v: solver.solve(v, mode="fused")) if k == 1
                      else (lambda v: solver.solve_many(v, mode="fused")))
            us_ref = timed(ref_fn, bs)
            us_fus = timed(fus_fn, bs)
            res["rhs"][k] = {"finalized_us": us_ref, "fused_us": us_fus,
                             "speedup": us_ref / us_fus}
            csv_row(f"fused_solve_{tag}_n{n}_s{stages}_k{k}", us_fus,
                    f"finalized={us_ref:.1f}us;"
                    f"speedup={us_ref / us_fus:.2f}x")
        res["speedup_marginal"] = res["rhs"][max(rhs_counts)]["speedup"]
        out[f"fused_{tag}_n{n}"] = res

    # AnalogPreconditioner apply inside pcg: systematic wire distortion at
    # sigma=0 keeps the preconditioned operator in the convergent regime
    # (TESTING.md regime map), so both modes run the same iteration count
    # and the wall-clock ratio isolates the inner-loop apply.
    from repro.hybrid import AnalogPreconditioner, matvec_from_dense, pcg
    cfg = AnalogConfig(array_size=n // 4,
                       nonideal=NonidealConfig(sigma=0.0, r_wire=1.0))
    a, b, _, _ = _mc_problem("wishart", n, 1, seed=0)
    mv = matvec_from_dense(a)
    res = {}
    for mode in ("reference", "fused"):
        pre = AnalogPreconditioner.program(a, jax.random.PRNGKey(7), cfg,
                                           stages=stages, mode=mode)
        run = jax.jit(lambda bb, p=pre: pcg(mv, bb, precond=p, x0=p(bb),
                                            tol=1e-8, maxiter=64))
        info = run(b)
        res[mode] = {"us": timed(run, b), "iters": int(info.iters),
                     "resnorm": float(info.resnorm)}
    res["speedup"] = res["reference"]["us"] / res["fused"]["us"]
    csv_row(f"fused_pcg_apply_n{n}", res["fused"]["us"],
            f"reference={res['reference']['us']:.1f}us;"
            f"speedup={res['speedup']:.2f}x;iters={res['fused']['iters']}")
    out[f"fused_pcg_n{n}"] = res

    # CI smoke: the whole-cascade Pallas megakernel in interpret mode (one
    # pallas_call walks every tile of a uniform two-stage schedule).
    n_s = 32
    cfg = AnalogConfig(array_size=n_s // 4,
                       nonideal=NonidealConfig(sigma=0.05))
    a, b, _, _ = _mc_problem("wishart", n_s, 1, seed=0)
    ap = blockamc.compile_arena(blockamc.finalize(
        blockamc.build_flat_plan(a, jax.random.PRNGKey(7), cfg, stages=2),
        cfg))
    x_k = blockamc.execute_arena(ap, b, use_kernel=True)
    x_j = blockamc.execute_arena(ap, b, use_kernel=False)
    err = float(jnp.max(jnp.abs(x_k - x_j)))
    us = timed(jax.jit(lambda v: blockamc.execute_arena(ap, v,
                                                        use_kernel=True)), b)
    csv_row(f"fused_kernel_interpret_n{n_s}", us, f"max_abs_diff={err:.2e}")
    out["fused_kernel_smoke"] = {"n": n_s, "interpret_us": us,
                                 "max_abs_diff_vs_jnp": err,
                                 "uniform_program": ap.program is not None}


def timed_flush_pair(refill, fn_a, fn_b, warmup: int = None,
                     iters: int = None):
    """Median microseconds for two queue-consuming strategies.

    `timed` cannot time a flush (the call empties the queue it measures),
    so each measurement is refill -> flush with only the flush on the
    clock; strategies alternate A, B, A, B, ... so drift on a shared
    runner biases both medians the same way instead of whichever ran
    second.  Honours the shared TIMED_WARMUP/TIMED_ITERS protocol.
    """
    from benchmarks import common
    warmup = common.TIMED_WARMUP if warmup is None else warmup
    iters = common.TIMED_ITERS if iters is None else iters
    for fn in (fn_a, fn_b):
        for _ in range(warmup):
            refill()
            jax.block_until_ready(fn())
    ts_a, ts_b = [], []
    for _ in range(iters):
        for fn, ts in ((fn_a, ts_a), (fn_b, ts_b)):
            refill()
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts.append((time.perf_counter() - t0) * 1e6)
    import numpy as _np
    return float(_np.median(ts_a)), float(_np.median(ts_b))


def packed_bench(out, n: int = 256):
    """Multi-tenant packed serving: one dispatch over (tenants x rhs)
    (ISSUE 5 acceptance).

    M same-signature tenants on the Fig. 8 two-stage config, k queued rhs
    each:

      packed_flush_*    the continuous-batching `SolverService.flush_all`
                        (signature-bucketed pack + ONE fused
                        execute_arena_packed dispatch) vs the per-matrix
                        flush loop over identical queues - the serving
                        acceptance headline `speedup_flush` (>= 3x at
                        M=16, k=8)
      packed_program_*  batched programming (`program_packed`: one jitted
                        vmapped partition/program/finalize/arena pipeline
                        over the matrix stack) vs M sequential per-matrix
                        pipeline runs - `speedup_program` (>= 4x at M=16)
      packed_kernel_smoke  the instance-axis whole-fleet Pallas megakernel
                        in interpret mode vs the stacked jnp path (CPU CI)
    """
    stages = 2
    k = 4 if SMOKE else 8
    tenants = TENANTS if TENANTS else ((4,) if SMOKE else (4, 16))
    cfg = AnalogConfig(array_size=n // 4,
                       nonideal=NonidealConfig(sigma=0.05))
    from repro.serve import SolverService
    for m in tenants:
        keys = jax.random.split(jax.random.PRNGKey(5), m)
        As = jnp.stack([_mc_problem("wishart", n, 1, seed=100 + i)[0]
                        for i in range(m)])

        # --- batched vs sequential programming -------------------------
        def seq_program():
            return [blockamc.compile_arena(blockamc.finalize(
                blockamc.build_flat_plan(As[i], keys[i], cfg,
                                         stages=stages), cfg))
                    for i in range(m)]

        us_seq = timed(seq_program)
        us_bat = timed(lambda: blockamc.program_packed(As, keys, cfg,
                                                       stages=stages))
        sp_prog = us_seq / us_bat
        csv_row(f"packed_program_m{m}_n{n}_s{stages}", us_bat,
                f"sequential={us_seq:.1f}us;speedup={sp_prog:.2f}x")
        out[f"packed_program_m{m}_n{n}"] = {
            "sequential_us": us_seq, "batched_us": us_bat,
            "speedup_program": sp_prog}

        # --- flush_all vs per-matrix flush loop ------------------------
        svc = SolverService(cfg, stages=stages)
        ids = [f"t{i}" for i in range(m)]
        for i, mid in enumerate(ids):
            svc.program(mid, As[i], keys[i])
        cols = {mid: [jax.random.normal(
            jax.random.fold_in(jax.random.PRNGKey(6), 1000 * i + j), (n,))
            for j in range(k)] for i, mid in enumerate(ids)}

        def refill():
            for mid in ids:
                for b in cols[mid]:
                    svc.submit(mid, b)

        def flush_loop():
            return [svc.flush(mid) for mid in ids]

        def flush_packed():
            return svc.flush_all()

        # A flush consumes its queue, so the timing loop is hand-rolled:
        # refill outside the measured region, and the two strategies
        # interleave measurement-for-measurement so shared-runner noise
        # hits both alike before the medians are compared.  The ratio is
        # an acceptance-gated number, so the median takes at least 13
        # interleaved pairs (a larger --bench-iters is honoured).
        from benchmarks import common
        us_loop, us_all = timed_flush_pair(
            refill, flush_loop, flush_packed,
            iters=max(common.TIMED_ITERS, 13))
        sp_flush = us_loop / us_all
        csv_row(f"packed_flush_m{m}_n{n}_s{stages}_k{k}", us_all,
                f"loop={us_loop:.1f}us;speedup={sp_flush:.2f}x")
        out[f"packed_flush_m{m}_n{n}_k{k}"] = {
            "flush_loop_us": us_loop, "flush_all_us": us_all,
            "speedup_flush": sp_flush}

    # CI smoke: the instance-axis megakernel (interpret mode) runs the
    # whole packed fleet's cascades as ONE pallas_call.
    n_s, m_s = 32, 3
    cfg_s = AnalogConfig(array_size=n_s // 4,
                         nonideal=NonidealConfig(sigma=0.05))
    As = jnp.stack([_mc_problem("wishart", n_s, 1, seed=200 + i)[0]
                    for i in range(m_s)])
    pp = blockamc.program_packed(As, jax.random.split(jax.random.PRNGKey(9),
                                                      m_s), cfg_s, stages=2)
    bs = jax.random.normal(jax.random.PRNGKey(10), (m_s, n_s, 2))
    x_k = blockamc.execute_arena_packed(pp, bs, use_kernel=True)
    x_j = blockamc.execute_arena_packed(pp, bs, use_kernel=False)
    err = float(jnp.max(jnp.abs(x_k - x_j)))
    us = timed(jax.jit(lambda v: blockamc.execute_arena_packed(
        pp, v, use_kernel=True)), bs)
    csv_row(f"packed_kernel_interpret_m{m_s}_n{n_s}", us,
            f"max_abs_diff={err:.2e}")
    out["packed_kernel_smoke"] = {"m": m_s, "n": n_s, "interpret_us": us,
                                  "max_abs_diff_vs_jnp": err,
                                  "uniform_program":
                                      pp.program_ops is not None}


def main():
    out = {}
    program_once_bench(out, n=128 if SMOKE else 256)
    fused_bench(out, n=128 if SMOKE else 256)
    packed_bench(out, n=128 if SMOKE else 256)
    mc_path_bench(out, n_sims=4 if SMOKE else 40)
    xbar_shapes = (((128, 256, 256),) if SMOKE
                   else ((256, 512, 512), (512, 1024, 1024)))
    for b, r, c in xbar_shapes:
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        v = jax.random.uniform(k1, (b, c), minval=-1, maxval=1)
        gp = jax.random.uniform(k2, (r, c), maxval=G0)
        gn = jax.random.uniform(k3, (r, c), maxval=G0)
        fn = jax.jit(lambda v, gp, gn: ref.crossbar_mvm_ref(
            v, gp, gn, g0=G0, dac_bits=8, adc_bits=8))
        us = timed(fn, v, gp, gn)
        gb = (v.size + gp.size + gn.size + b * r) * 4 / 1e9
        csv_row(f"crossbar_mvm_ref_{b}x{r}x{c}", us, f"GB={gb:.3f}")
        out[f"crossbar_{b}x{r}x{c}"] = us

    # Leading-dim batched entry point: one (L, R, C) shape-bucket stack of
    # the flat executor driven in a single call (oracle path timed; the
    # Pallas kernel is parity-checked in tests/test_kernels.py).
    batched_shapes = (((4, 64, 64, 64),) if SMOKE
                      else ((16, 64, 64, 64), (16, 128, 128, 128)))
    for l, b, r, c in batched_shapes:
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
        v = jax.random.uniform(k1, (l, b, c), minval=-1, maxval=1)
        gp = jax.random.uniform(k2, (l, r, c), maxval=G0)
        gn = jax.random.uniform(k3, (l, r, c), maxval=G0)
        fn = jax.jit(jax.vmap(lambda vv, gpp, gnn: ref.crossbar_mvm_ref(
            vv, gpp, gnn, g0=G0, dac_bits=8, adc_bits=8)))
        us = timed(fn, v, gp, gn)
        gb = (v.size + gp.size + gn.size + l * b * r) * 4 / 1e9
        csv_row(f"crossbar_mvm_batched_ref_{l}x{b}x{r}x{c}", us,
                f"GB={gb:.3f}")
        out[f"crossbar_batched_{l}x{b}x{r}x{c}"] = us

    for n in ((256,) if SMOKE else (512, 1024)):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
        a4 = jax.random.normal(k1, (n, n))
        a3 = jax.random.normal(k2, (n, n))
        w = jax.random.normal(k3, (n, n))
        fn = jax.jit(lambda a4, a3, w: ref.schur_update_ref(a4, a3, w))
        us = timed(fn, a4, a3, w)
        csv_row(f"schur_update_ref_{n}", us,
                f"GFLOP={2 * n ** 3 / 1e9:.2f}")
        out[f"schur_{n}"] = us
    save_json("kernel_bench", out)
    return out


if __name__ == "__main__":
    main()

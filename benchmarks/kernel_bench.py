"""Pallas kernel microbenchmarks.

On this CPU container kernels execute in interpret mode (Python per grid
step), so wall times here measure the *oracle* jnp path as the meaningful
number and the interpret path only for correctness parity; the TPU numbers
come from the roofline analysis (EXPERIMENTS.md).  derived = model GB
touched per call.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, save_json, timed
from repro.kernels import ops, ref

G0 = 100e-6


def main():
    out = {}
    for b, r, c in ((256, 512, 512), (512, 1024, 1024)):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        v = jax.random.uniform(k1, (b, c), minval=-1, maxval=1)
        gp = jax.random.uniform(k2, (r, c), maxval=G0)
        gn = jax.random.uniform(k3, (r, c), maxval=G0)
        fn = jax.jit(lambda v, gp, gn: ref.crossbar_mvm_ref(
            v, gp, gn, g0=G0, dac_bits=8, adc_bits=8))
        us = timed(fn, v, gp, gn)
        gb = (v.size + gp.size + gn.size + b * r) * 4 / 1e9
        csv_row(f"crossbar_mvm_ref_{b}x{r}x{c}", us, f"GB={gb:.3f}")
        out[f"crossbar_{b}x{r}x{c}"] = us

    for n in (512, 1024):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
        a4 = jax.random.normal(k1, (n, n))
        a3 = jax.random.normal(k2, (n, n))
        w = jax.random.normal(k3, (n, n))
        fn = jax.jit(lambda a4, a3, w: ref.schur_update_ref(a4, a3, w))
        us = timed(fn, a4, a3, w)
        csv_row(f"schur_update_ref_{n}", us,
                f"GFLOP={2 * n ** 3 / 1e9:.2f}")
        out[f"schur_{n}"] = us
    save_json("kernel_bench", out)
    return out


if __name__ == "__main__":
    main()

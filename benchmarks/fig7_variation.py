"""Paper Fig. 7: device-variation accuracy, Wishart + Toeplitz, 40 sims.

sigma = 0.05 G0 Gaussian conductance noise, one-stage BlockAMC vs original
AMC across 8..512.  Paper claims: near-identical for Wishart (slight
BlockAMC edge), remarkable BlockAMC improvement for Toeplitz at scale.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (N_SIMS_PAPER, SIZES_PAPER, csv_row, mc_errors,
                               save_json)
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig


def run(n_sims=None, sizes=None):
    # resolve module attrs at call time so run.py's fast-mode overrides stick
    n_sims = N_SIMS_PAPER if n_sims is None else n_sims
    sizes = SIZES_PAPER if sizes is None else sizes
    out = {}
    for family in ("wishart", "toeplitz"):
        rows = []
        for n in sizes:
            cfg = AnalogConfig(array_size=max(n // 2, 4),
                               nonideal=NonidealConfig(sigma=0.05))
            eb = mc_errors(family, n, cfg, "blockamc", n_sims, stages=1)
            eo = mc_errors(family, n, cfg, "original", n_sims)
            rows.append({
                "n": n,
                "block_median": float(np.median(eb)),
                "orig_median": float(np.median(eo)),
                "block_mean": float(np.mean(eb)),
                "orig_mean": float(np.mean(eo)),
            })
        out[family] = rows
    return out


def main():
    out = run()
    save_json("fig7_variation", out)
    for family, rows in out.items():
        better = sum(1 for r in rows if r["block_median"] <= r["orig_median"])
        big = rows[-1]
        csv_row(f"fig7_{family}_block_better", 0.0,
                f"{better}/{len(rows)} sizes;"
                f"n{big['n']}_block={big['block_median']:.3f};"
                f"n{big['n']}_orig={big['orig_median']:.3f}")
    return out


if __name__ == "__main__":
    main()

"""Serving-engine SLO benchmark: open-loop Poisson traffic, with and
without a scripted fault schedule.

Open-loop means arrivals follow a pre-generated Poisson schedule whatever
the engine's state (the standard way to measure a serving system - a
closed loop would slow its own offered load down exactly when the engine
struggles, hiding tail latency).  One seeded generator fixes the arrival
times, tenant choices and right-hand sides, so baseline and faulted runs
see byte-identical traffic and the chaos schedule (dispatch-counter
keyed) is deterministic too.

Reported per run (JSON -> artifacts/bench/engine.json, report-only keys:
latencies in `_ms`, rates as ratios, so the nightly diff_bench prints
them without gating - serving tails on shared CI boxes are too noisy to
gate at +-25%):

* p50_ms / p99_ms - submit->answer latency percentiles
* goodput_rps     - answers within deadline per wall-clock second
* miss_rate       - (expired + answered-late) / admitted
* recovery_ms     - quarantine -> healthy wall time (faulted run)
* mode mix        - analog vs digital-fallback answers

The faulted run injects a severe stuck-at DeviceFault on one tenant plus
one scripted dispatch exception mid-stream; the healthy tenants' p99 and
the recovery time are the numbers the ISSUE acceptance criterion tracks.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import numpy as np

from benchmarks.common import csv_row, save_json
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import random_rhs, wishart
from repro.runtime import ChaosInjector, DeviceFault, DispatchException
from repro.serve import AsyncSolverEngine, BackpressureError, SolverService

SMOKE = False

# severe stuck-at: guaranteed to trip the canary, never recoverable by luck
SEVERE = NonidealConfig(sigma=0.02, p_stuck_off=0.6, g_stuck_off=0.0)


def _percentile_ms(lat_s, q):
    return float(np.percentile(np.asarray(lat_s) * 1e3, q)) if len(lat_s) \
        else 0.0


def run_traffic(*, n, m, rate_hz, n_requests, deadline_s, chaos_events=(),
                seed=0, faulted_tenant="b0"):
    """One open-loop run; returns the metrics dict."""
    cfg = AnalogConfig(array_size=max(n // 2, 4),
                       nonideal=NonidealConfig(sigma=0.02))
    svc = SolverService(cfg, stages=1)
    chaos = ChaosInjector(list(chaos_events)) if chaos_events else None
    eng = AsyncSolverEngine(svc, max_batch=8, flush_interval=0.02,
                            max_pending=512, retries=2, backoff=0.0,
                            chaos=chaos)
    key = jax.random.PRNGKey(seed)
    for i in range(m):
        eng.program("b%d" % i, wishart(jax.random.fold_in(key, i), n),
                    jax.random.fold_in(key, 100 + i))

    # pre-generate the whole trace: identical traffic across runs
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, n_requests))
    tenants = rng.integers(0, m, n_requests)
    rhs = [np.asarray(random_rhs(jax.random.fold_in(key, 500 + i), n))
           for i in range(n_requests)]

    futs, rejected = [], 0
    with eng:
        t0 = time.perf_counter()
        for i in range(n_requests):
            lag = arrivals[i] - (time.perf_counter() - t0)
            if lag > 0:
                time.sleep(lag)
            try:
                futs.append(eng.submit("b%d" % tenants[i], rhs[i],
                                       deadline_s=deadline_s))
            except BackpressureError:
                rejected += 1          # open loop: admission says later
        results, typed_errors = [], 0
        for f in futs:
            try:
                results.append(f.result(timeout=600))
            except Exception:                      # noqa: BLE001
                typed_errors += 1      # typed engine error, never a hang
        wall = time.perf_counter() - t0

    lat = [r.latency_s for r in results]
    in_slo = sum(1 for r in results if not r.deadline_missed)
    admitted = len(futs)
    st = eng.stats
    return {
        "requests": n_requests,
        "admitted": admitted,
        "rejected_backpressure": rejected,
        "answered": len(results),
        "typed_errors": typed_errors,
        "p50_ms": _percentile_ms(lat, 50),
        "p99_ms": _percentile_ms(lat, 99),
        "wall_ms": wall * 1e3,
        "offered_rps": n_requests / wall,
        "goodput_rps": in_slo / wall,
        "miss_rate": (st.deadline_misses / admitted) if admitted else 0.0,
        "analog_answers": sum(1 for r in results if r.mode == "analog"),
        "digital_answers": sum(1 for r in results if r.mode == "digital"),
        "dispatches": st.dispatches,
        "retries": st.retries,
        "quarantines": st.quarantines,
        "reprograms": st.reprograms,
        "degraded": st.degraded,
        "recovery_ms": [s * 1e3 for s in st.recovery_s],
        "chaos_log": ([(i, type(e).__name__) for i, e in chaos.log]
                      if chaos else []),
    }


def main():
    if SMOKE:
        n, m, n_requests, rate_hz = 16, 4, 48, 80.0
    else:
        n, m, n_requests, rate_hz = 32, 8, 200, 150.0
    deadline_s = 5.0
    # exception before the device fault so both fire even in the short
    # smoke run (a 48-request smoke only reaches ~7 dispatch attempts)
    fault_schedule = (
        DispatchException(at_dispatch=3),
        DeviceFault(at_dispatch=5, matrix_id="b0", nonideal=SEVERE),
    )
    # no `_s`/`_us` suffixes in the payload: diff_bench's name-based rule
    # would gate them, and serving numbers on shared runners are
    # deliberately report-only (see module docstring)
    out = {"params": {"n": n, "tenants": m, "requests": n_requests,
                      "rate_hz": rate_hz, "deadline_sec": deadline_s,
                      "smoke": SMOKE}}
    base = run_traffic(n=n, m=m, rate_hz=rate_hz, n_requests=n_requests,
                       deadline_s=deadline_s)
    out["baseline"] = base
    csv_row("engine_baseline_m%d_n%d" % (m, n), 0.0,
            "p50_ms=%.1f p99_ms=%.1f goodput=%.0f/s miss=%.3f" %
            (base["p50_ms"], base["p99_ms"], base["goodput_rps"],
             base["miss_rate"]))
    faulted = run_traffic(n=n, m=m, rate_hz=rate_hz, n_requests=n_requests,
                          deadline_s=deadline_s,
                          chaos_events=fault_schedule)
    out["faulted"] = faulted
    rec = faulted["recovery_ms"][0] if faulted["recovery_ms"] else float("nan")
    csv_row("engine_faulted_m%d_n%d" % (m, n), 0.0,
            "p99_ms=%.1f goodput=%.0f/s miss=%.3f recovery_ms=%.0f "
            "quarantines=%d" %
            (faulted["p99_ms"], faulted["goodput_rps"],
             faulted["miss_rate"], rec, faulted["quarantines"]))
    save_json("engine", out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="nightly chaos smoke: 4 tenants, ~50 requests")
    if ap.parse_args().smoke:
        SMOKE = True
    main()

"""Drift-maintenance benchmark: self-healing availability + repair cost.

Two scenarios (artifacts/bench/maint.json):

* **availability** - one matrix serving under continuous power-law
  retention drift on a simulated `DeviceClock`, identical clock steps and
  traffic for two engines:

    - `selfheal`  - background scrubbing on: per-block canary probes feed
                    EWMA/CUSUM trends, degraded arrays are block-repaired
                    *before* the SLO canary trips;
    - `reactive`  - scrubbing off: the engine only has the reactive
                    ladder (canary trip -> quarantine -> full re-program).

  The acceptance story: `selfheal_quarantines == 0` and
  `selfheal_deadline_misses == 0` over a horizon where the reactive
  baseline quarantines repeatedly (`reactive_quarantines > 0`).

* **repair_cost** - the ISSUE ratio on the paper's two-stage 256^2 plan
  under a write-verify programming config: median wall time of
  `ProgrammedSolver.repaired` on a degraded fraction of the arrays vs a
  full `ProgrammedSolver.program`.  `repair_speedup` (full / repair,
  acceptance floor 2x) is recorded even under --smoke - the ratio IS the
  deliverable, smoke only trims the availability horizon.

All keys are report-only for the nightly diff_bench (`_ms` suffixes, the
ratio, counters): serving scenarios and programming times on shared CI
boxes are too noisy to gate at +-25%.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import numpy as np

from benchmarks.common import csv_row, save_json
from repro.core import blockamc
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import random_rhs, wishart
from repro.serve import (AsyncSolverEngine, DeviceClock, MaintenanceConfig,
                         SolverService)

SMOKE = False

DRIFT = NonidealConfig(sigma=0.0, drift_nu=0.05)
MCFG = MaintenanceConfig(scrub_blocks_per_cycle=16, block_trip=0.02,
                         repair_batch=16)
# write-verify programming config for the repair-cost scenario: repair
# pays the same per-block mapping + verify loop a full program would
WV = NonidealConfig(sigma=0.02, r_wire=1.0, wire_model="first_order",
                    compensate_wire=True, wv_iters=3)


def run_drift(*, scrub: bool, n: int, waves: int, per_wave: int,
              dt: float, seed: int = 0) -> dict:
    """One aging run: advance the clock, quiesce the scrubber (no-op when
    scrubbing is off), serve a wave, repeat.  Identical clock steps and
    right-hand sides for both engines."""
    cfg = AnalogConfig(array_size=max(n // 2, 4), nonideal=DRIFT)
    key = jax.random.PRNGKey(seed)
    rhs = [np.asarray(random_rhs(jax.random.fold_in(key, 500 + i), n))
           for i in range(waves * per_wave)]
    clock = DeviceClock()
    svc = SolverService(cfg, stages=2)
    eng = AsyncSolverEngine(svc, clock=clock, scrub=scrub, maintenance=MCFG,
                            flush_interval=0.01, health_floor=0.05,
                            name="selfheal" if scrub else "reactive")
    misses = 0
    t0 = time.perf_counter()
    with eng:
        eng.program("m", wishart(key, n), jax.random.fold_in(key, 1))
        i = 0
        for _ in range(waves):
            clock.advance(dt)
            if scrub:
                eng.maintenance_quiesce(120.0)
            futs = []
            for _ in range(per_wave):
                futs.append(eng.submit("m", rhs[i]))
                i += 1
            eng.flush_now()
            for f in futs:
                misses += f.result(timeout=120).deadline_missed
        h = eng.health()
    wall = time.perf_counter() - t0
    g = h["maintenance"].get("m", {})
    return {
        "answered": h["answered"],
        "quarantines": h["quarantines"],
        "deadline_misses": misses,
        "scrub_probes": h["scrub_probes"],
        "repairs": h["repairs"],
        "blocks_repaired": h["blocks_repaired"],
        "wall_ms": wall * 1e3,
        # report-only drift gauges at end of horizon
        "worst_dev": g.get("worst_dev", 0.0),
        "trend_slope": g.get("trend_slope", 0.0),
        "scrub_backlog": g.get("scrub_backlog", 0.0),
    }


def _median_ms(fn, warmup: int, iters: int) -> float:
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(ts))


def repair_cost(n: int = 256, stages: int = 2,
                degraded_fraction: float = 0.125) -> dict:
    """Median cost of block repair vs full re-program, two-stage n^2."""
    cfg = AnalogConfig(array_size=n // 2, nonideal=WV)
    key = jax.random.PRNGKey(7)
    a = wishart(key, n)
    k1, k2 = jax.random.split(key)
    solver = blockamc.ProgrammedSolver.program(a, k1, cfg, stages)
    refs = [r.ref for r in solver.block_map()]
    k_rep = max(1, int(round(len(refs) * degraded_fraction)))
    subset = refs[::max(1, len(refs) // k_rep)][:k_rep]

    program_ms = _median_ms(
        lambda: blockamc.ProgrammedSolver.program(a, k2, cfg, stages),
        warmup=1, iters=3)
    repair_ms = _median_ms(
        lambda: solver.repaired(subset, k2), warmup=1, iters=3)
    return {
        "n": n,
        "stages": stages,
        "num_arrays": len(refs),
        "repaired_blocks": k_rep,
        "degraded_fraction": k_rep / len(refs),
        "program_ms": program_ms,
        "repair_ms": repair_ms,
        "repair_speedup": program_ms / repair_ms if repair_ms > 0
        else float("nan"),
    }


def main():
    if SMOKE:
        n, waves, per_wave = 16, 6, 3
    else:
        n, waves, per_wave = 16, 12, 4
    dt = 0.6

    out = {"params": {"n": n, "waves": waves, "per_wave": per_wave,
                      "dt": dt, "drift_nu": DRIFT.drift_nu,
                      "block_trip": MCFG.block_trip, "smoke": SMOKE}}

    heal = run_drift(scrub=True, n=n, waves=waves, per_wave=per_wave, dt=dt)
    react = run_drift(scrub=False, n=n, waves=waves, per_wave=per_wave,
                      dt=dt)
    out["selfheal"] = heal
    out["reactive"] = react
    # the acceptance keys, hoisted for the artifact reader
    out["selfheal_quarantines"] = heal["quarantines"]
    out["selfheal_deadline_misses"] = heal["deadline_misses"]
    out["reactive_quarantines"] = react["quarantines"]
    csv_row("maint_selfheal_n%d_w%d" % (n, waves), 0.0,
            "quarantines=%d misses=%d repairs=%d blocks=%d probes=%d" %
            (heal["quarantines"], heal["deadline_misses"], heal["repairs"],
             heal["blocks_repaired"], heal["scrub_probes"]))
    csv_row("maint_reactive_n%d_w%d" % (n, waves), 0.0,
            "quarantines=%d misses=%d (no scrubbing)" %
            (react["quarantines"], react["deadline_misses"]))

    cost = repair_cost()
    out["repair_cost"] = cost
    out["repair_speedup"] = cost["repair_speedup"]
    csv_row("maint_repair_cost_n%d" % cost["n"], 0.0,
            "program_ms=%.1f repair_ms=%.1f (%d/%d blocks) speedup=%.1fx" %
            (cost["program_ms"], cost["repair_ms"],
             cost["repaired_blocks"], cost["num_arrays"],
             cost["repair_speedup"]))
    save_json("maint", out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: shorter aging horizon (the 256^2 "
                         "repair-cost ratio always runs)")
    if ap.parse_args().smoke:
        SMOKE = True
    main()

"""Replicated-fleet benchmark: open-loop Poisson traffic through the
router, replica-loss recovery with and without checkpoint restore.

Open-loop (pre-generated Poisson arrivals, like engine_bench) so baseline
and chaos runs see byte-identical traffic.  Three scenarios share one
trace:

* baseline        - N replicas, no chaos: routing + hedging overhead over
                    a single engine is the p50/p99 story.
* death_restore   - r0's worker dies mid-stream (scripted `ReplicaDeath`);
                    the replacement restores programmed state from the
                    `ProgramStore` checkpoint.
* death_reprogram - same death, but every checkpoint was value-corrupted
                    after programming: the canary rejects each restore and
                    recovery pays full write-verify re-programming.

The headline number is `recovery_ratio` = re-program recovery time /
restore recovery time - the factor the durable-checkpoint path buys,
the ISSUE acceptance metric (artifacts/bench/router.json).  Recovery
time per scenario is the summed per-matrix state-rebuild time on the
replacement replica (`FleetStats.restore_s` / `reprogram_s`).

All keys are report-only for the nightly diff_bench (latencies `_ms`,
rates `_rps`/`_rate`, the ratio): serving tails and programming times on
shared CI boxes are too noisy to gate at +-25%.
"""
from __future__ import annotations

import argparse
import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import numpy as np

from benchmarks.common import csv_row, save_json
from repro.checkpoint import ProgramStore
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import random_rhs, wishart
from repro.runtime import ChaosInjector, ReplicaDeath
from repro.serve import ReplicatedSolverFleet, SolverService

SMOKE = False


def _percentile_ms(lat_s, q):
    return float(np.percentile(np.asarray(lat_s) * 1e3, q)) if len(lat_s) \
        else 0.0


def run_traffic(*, n, m, n_replicas, rate_hz, n_requests, deadline_s,
                chaos_events=(), damage=None, seed=0):
    """One open-loop run through a fresh fleet; returns the metrics dict.

    `damage(store)` runs after programming (checkpoints saved) and before
    traffic - the hook the corruption scenario uses.
    """
    cfg = AnalogConfig(array_size=max(n // 2, 4),
                       nonideal=NonidealConfig(sigma=0.02))
    chaos = ChaosInjector(list(chaos_events)) if chaos_events else None
    store_dir = tempfile.mkdtemp(prefix="router_bench_store_")
    store = ProgramStore(store_dir)
    fleet = ReplicatedSolverFleet(
        lambda: SolverService(cfg, stages=1), n_replicas,
        engine_kw=dict(max_batch=8, flush_interval=0.01, max_pending=512,
                       retries=2, backoff=0.0),
        store=store, chaos=chaos)

    key = jax.random.PRNGKey(seed)
    # pre-generate the whole trace: identical traffic across scenarios
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, n_requests))
    tenants = rng.integers(0, m, n_requests)
    rhs = [np.asarray(random_rhs(jax.random.fold_in(key, 500 + i), n))
           for i in range(n_requests)]

    try:
        with fleet:
            for i in range(m):
                fleet.program("b%d" % i,
                              wishart(jax.random.fold_in(key, i), n),
                              jax.random.fold_in(key, 100 + i))
            if damage is not None:
                damage(store)

            futs = []
            t0 = time.perf_counter()
            for i in range(n_requests):
                lag = arrivals[i] - (time.perf_counter() - t0)
                if lag > 0:
                    time.sleep(lag)
                futs.append(fleet.submit("b%d" % tenants[i], rhs[i],
                                         deadline_s=deadline_s))
            results, typed_errors = [], 0
            for f in futs:
                try:
                    results.append(f.result(timeout=600))
                except Exception:                  # noqa: BLE001
                    typed_errors += 1  # typed fleet error, never a hang
            wall = time.perf_counter() - t0
            if chaos is not None:
                # recovery completes asynchronously; bound the wait
                t_end = time.monotonic() + 60.0
                while (fleet.stats.replacements < 1
                       and time.monotonic() < t_end):
                    time.sleep(0.02)
    finally:
        shutil.rmtree(store_dir, ignore_errors=True)

    lat = [r.latency_s for r in results]
    in_slo = sum(1 for r in results if not r.deadline_missed)
    st = fleet.stats
    recovery_ms = 1e3 * (sum(st.restore_s) if st.restores
                         else sum(st.reprogram_s))
    return {
        "requests": n_requests,
        "answered": len(results),
        "typed_errors": typed_errors,
        "p50_ms": _percentile_ms(lat, 50),
        "p99_ms": _percentile_ms(lat, 99),
        "wall_ms": wall * 1e3,
        "offered_rps": n_requests / wall,
        "goodput_rps": in_slo / wall,
        "miss_rate": (sum(1 for r in results if r.deadline_missed)
                      / len(results)) if results else 0.0,
        "hedges": st.hedges,
        "replays": st.replays,
        "deaths": st.deaths,
        "replacements": st.replacements,
        "restores": st.restores,
        "reprogram_fallbacks": st.reprogram_fallbacks,
        "rejected_checkpoints": st.rejected_checkpoints,
        "restore_ms": [s * 1e3 for s in st.restore_s],
        "reprogram_ms": [s * 1e3 for s in st.reprogram_s],
        "recovery_ms": recovery_ms,
        "chaos_log": ([(i, type(e).__name__) for i, e in chaos.log]
                      if chaos else []),
    }


def main():
    if SMOKE:
        n, m, n_replicas, n_requests, rate_hz = 16, 2, 2, 40, 100.0
    else:
        n, m, n_replicas, n_requests, rate_hz = 32, 4, 3, 160, 150.0
    deadline_s = 5.0
    death = (ReplicaDeath(at_dispatch=2, replica="r0"),)

    out = {"params": {"n": n, "tenants": m, "replicas": n_replicas,
                      "requests": n_requests, "rate_hz": rate_hz,
                      "deadline_sec": deadline_s, "smoke": SMOKE}}

    base = run_traffic(n=n, m=m, n_replicas=n_replicas, rate_hz=rate_hz,
                       n_requests=n_requests, deadline_s=deadline_s)
    out["baseline"] = base
    csv_row("router_baseline_r%d_m%d_n%d" % (n_replicas, m, n), 0.0,
            "p50_ms=%.1f p99_ms=%.1f goodput=%.0f/s miss=%.3f" %
            (base["p50_ms"], base["p99_ms"], base["goodput_rps"],
             base["miss_rate"]))

    restore = run_traffic(n=n, m=m, n_replicas=n_replicas, rate_hz=rate_hz,
                          n_requests=n_requests, deadline_s=deadline_s,
                          chaos_events=death)
    out["death_restore"] = restore
    csv_row("router_death_restore_r%d_m%d_n%d" % (n_replicas, m, n), 0.0,
            "p99_ms=%.1f replays=%d restores=%d recovery_ms=%.1f" %
            (restore["p99_ms"], restore["replays"], restore["restores"],
             restore["recovery_ms"]))

    reprog = run_traffic(
        n=n, m=m, n_replicas=n_replicas, rate_hz=rate_hz,
        n_requests=n_requests, deadline_s=deadline_s, chaos_events=death,
        damage=lambda store: [store.corrupt(mid, "values")
                              for mid in store.matrix_ids()])
    out["death_reprogram"] = reprog
    csv_row("router_death_reprogram_r%d_m%d_n%d" % (n_replicas, m, n), 0.0,
            "p99_ms=%.1f rejected=%d reprograms=%d recovery_ms=%.1f" %
            (reprog["p99_ms"], reprog["rejected_checkpoints"],
             reprog["reprogram_fallbacks"], reprog["recovery_ms"]))

    ratio = (reprog["recovery_ms"] / restore["recovery_ms"]
             if restore["recovery_ms"] > 0 else float("nan"))
    out["recovery_ratio"] = ratio
    csv_row("router_recovery_ratio", 0.0,
            "reprogram_over_restore=%.1fx (restore=%.1fms reprogram=%.1fms)"
            % (ratio, restore["recovery_ms"], reprog["recovery_ms"]))
    save_json("router", out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: 2 replicas, 2 tenants, ~40 requests")
    if ap.parse_args().smoke:
        SMOKE = True
    main()

"""Distributed BlockAMC benchmark: the solver as a mesh-parallel service.

Executes the vectorised tile solver end-to-end on the host device(s) at a
real size (n=1024, 3 stages) and reports accuracy + wall time; the
production-mesh lowering of the same code path is covered by the dry-run
(launch/dryrun.py lowers LM cells; core/distributed is exercised in tests
with a host mesh).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, matrix_of, save_json, timed
from repro.core import distributed
from repro.core.analog import AnalogConfig
from repro.core.metrics import relative_error
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import random_rhs


def main():
    n, stages = 1024, 3
    ka, kb, kn = jax.random.split(jax.random.PRNGKey(0), 3)
    a = matrix_of("wishart", ka, n)
    b = random_rhs(kb, n)
    x_ref = jnp.linalg.solve(a, b)

    rows = []
    us = 0.0
    for sigma in (0.0, 0.01, 0.05):
        cfg = AnalogConfig(array_size=n // 2 ** stages,
                           nonideal=NonidealConfig(sigma=sigma))
        solve = jax.jit(lambda key: distributed.solve_distributed(
            a, b, key, cfg, stages=stages))
        err = float(relative_error(x_ref, solve(kn)))
        if sigma == 0.05:
            us = timed(solve, kn, warmup=1, iters=3)
        rows.append({"sigma": sigma, "relerr": err})
    save_json("distributed_solver", {"n": n, "stages": stages, "rows": rows,
                                     "us_per_solve": us})
    for r in rows:
        csv_row(f"distributed_blockamc_n1024_s3_sigma{r['sigma']}", us,
                f"relerr={r['relerr']:.2e}")
    return rows


if __name__ == "__main__":
    main()

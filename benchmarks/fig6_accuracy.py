"""Paper Fig. 6: ideal-mapping accuracy (finite-OPA-gain HSPICE stand-in).

(a) step-by-step cascade signals vs the numerical solver (256x256 Wishart),
(b) final solutions, (c) relative error vs matrix size, original AMC vs
one-stage BlockAMC.  Device mapping is ideal (no conductance noise, no wire
resistance); the error floor comes from finite OPA open-loop gain, which is
what makes smaller BlockAMC arrays intrinsically more accurate (DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SIZES_PAPER, csv_row, matrix_of, save_json, timed
from repro.core import analog, blockamc
from repro.core.analog import AnalogConfig
from repro.core.metrics import relative_error
from repro.data.matrices import random_rhs

OPA_GAIN = 1e4


def step_by_step(n: int = 256):
    """Fig. 6(a): the five cascade signals vs numpy, one-stage solver."""
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    a = matrix_of("wishart", ka, n)
    b = random_rhs(kb, n)
    cfg = AnalogConfig(array_size=n // 2, opa_gain=OPA_GAIN)
    m = n // 2
    a1, a2 = a[:m, :m], a[:m, m:]
    a3, a4 = a[m:, :m], a[m:, m:]
    f, g = b[:m], b[m:]
    scale = 1.0 / jnp.max(jnp.abs(a))
    keys = jax.random.split(jax.random.PRNGKey(1), 4)
    p1 = analog.map_matrix(a1, keys[0], cfg, scale)
    p2 = analog.map_tiled(a2, keys[1], cfg, scale)
    p3 = analog.map_tiled(a3, keys[2], cfg, scale)
    a4s = a4 - a3 @ jnp.linalg.solve(a1, a2)
    p4 = analog.map_matrix(a4s, keys[3], cfg, scale)

    # numerical references (scaled domain)
    y_t = jnp.linalg.solve(a1, f)
    g_t = a3 @ y_t
    z_ref = jnp.linalg.solve(a4s, g - g_t)
    f_t = a2 @ z_ref
    y_ref = jnp.linalg.solve(a1, f - f_t)

    neg_yt = analog.amc_inv(p1, f, cfg)                     # step 1
    gt = analog.amc_mvm_tiled(p3, neg_yt, cfg)              # step 2
    z = analog.amc_inv(p4, -g + gt, cfg)                    # step 3 (=+z/c)
    neg_ft = analog.amc_mvm_tiled(p2, z, cfg)               # step 4
    neg_y = analog.amc_inv(p1, f + neg_ft, cfg)             # step 5

    # Scale bookkeeping: arrays hold c*A (c = scale), so INV outputs are
    # (true)/c and MVM outputs of INV results are unscaled (c cancels).
    steps = {
        "step1_yt": float(relative_error(y_t, -neg_yt * scale)),
        "step2_gt": float(relative_error(g_t, gt)),
        "step3_z": float(relative_error(z_ref, z * scale)),
        "step4_ft": float(relative_error(f_t, -neg_ft)),
        "step5_y": float(relative_error(y_ref, -neg_y * scale)),
    }
    return steps


def error_vs_size():
    """Fig. 6(c)."""
    rows = []
    for n in SIZES_PAPER:
        ka, kb, kn = jax.random.split(jax.random.PRNGKey(2), 3)
        a = matrix_of("wishart", ka, n)
        b = random_rhs(kb, n)
        x_ref = jnp.linalg.solve(a, b)
        cfg = AnalogConfig(array_size=max(n // 2, 4), opa_gain=OPA_GAIN)
        xb = blockamc.solve(a, b, kn, cfg, stages=1)
        xo = blockamc.solve_original(a, b, kn, cfg)
        rows.append({"n": n,
                     "blockamc": float(relative_error(x_ref, xb)),
                     "original": float(relative_error(x_ref, xo))})
    return rows


def main():
    steps = step_by_step()
    rows = error_vs_size()
    save_json("fig6_accuracy", {"steps_256": steps, "error_vs_size": rows})
    # timing of a full one-stage 256 solve (CPU wall time, context only)
    ka, kb, kn = jax.random.split(jax.random.PRNGKey(0), 3)
    a = matrix_of("wishart", ka, 256)
    b = random_rhs(kb, 256)
    cfg = AnalogConfig(array_size=128, opa_gain=OPA_GAIN)
    fn = jax.jit(lambda: blockamc.solve(a, b, kn, cfg, stages=1))
    us = timed(fn)
    final = rows[-2]  # n = 256
    csv_row("fig6_step_cascade_maxerr", us,
            f"max_step_relerr={max(steps.values()):.2e}")
    csv_row("fig6_block_vs_orig_n256", us,
            f"block={final['blockamc']:.4f};orig={final['original']:.4f}")
    better = sum(1 for r in rows if r["blockamc"] <= r["original"])
    csv_row("fig6_block_better_fraction", us, f"{better}/{len(rows)}")
    return {"steps": steps, "rows": rows}


if __name__ == "__main__":
    main()

"""Paper Fig. 10: area & power breakdown of the three 512x512 solvers.

Reproduces the headline numbers (one-stage: 48.83% area / 40% power saving;
two-stage: 12.3% / 37.4%) from the component-count model calibrated per
core/area_energy.py, plus the macro timing model (latency / initiation
interval) from core/macro.py.
"""
from __future__ import annotations

from benchmarks.common import csv_row, save_json
from repro.core import area_energy, macro


def main():
    rep = area_energy.report()
    sav = area_energy.savings(rep)
    perf = {s: macro.solver_performance(s, n_solves=16)
            for s in ("original", "one_stage", "two_stage")}
    save_json("fig10_area_power", {"report": rep, "savings": sav,
                                   "macro_perf": perf})
    csv_row("fig10_area_totals_mm2", 0.0,
            f"orig={rep['area']['original']['total']:.5f};"
            f"one={rep['area']['one_stage']['total']:.5f};"
            f"two={rep['area']['two_stage']['total']:.5f}")
    csv_row("fig10_savings", 0.0,
            f"area_one={sav['area']['one_stage']:.4f};"
            f"area_two={sav['area']['two_stage']:.4f};"
            f"power_one={sav['power']['one_stage']:.4f};"
            f"power_two={sav['power']['two_stage']:.4f}")
    csv_row("fig10_macro_cycles", 0.0,
            f"one_latency={perf['one_stage']['latency_cycles']};"
            f"one_II={perf['one_stage']['initiation_interval']};"
            f"two_latency={perf['two_stage']['latency_cycles']};"
            f"two_II={perf['two_stage']['initiation_interval']}")
    return {"savings": sav}


if __name__ == "__main__":
    main()

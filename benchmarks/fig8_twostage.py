"""Paper Fig. 8: two-stage BlockAMC (256x256 -> 16 arrays of 64x64).

(a/b) stage-resolved INV accuracy, (c) final solutions, (d) error vs size
for the two-stage solver vs original AMC, all with device variation.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (N_SIMS_PAPER, csv_row, mc_errors, save_json)
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig

SIZES = (64, 128, 256, 512)


def run(n_sims=None):
    # resolve at call time so run.py's fast-mode overrides stick
    n_sims = N_SIMS_PAPER if n_sims is None else n_sims
    rows = []
    for n in SIZES:
        cfg = AnalogConfig(array_size=max(n // 4, 4),
                           nonideal=NonidealConfig(sigma=0.05))
        e2 = mc_errors("wishart", n, cfg, "blockamc", n_sims, stages=2)
        eo = mc_errors("wishart", n, cfg, "original", n_sims)
        rows.append({"n": n,
                     "two_stage_median": float(np.median(e2)),
                     "orig_median": float(np.median(eo))})
    return rows


def structure_check():
    """16 x (64x64) leaves for n=256, stages=2 (paper's partitioning)."""
    from repro.core import blockamc
    from repro.data.matrices import wishart
    a = wishart(jax.random.PRNGKey(0), 256)
    cfg = AnalogConfig(array_size=64)
    plan = blockamc.build_plan(a, jax.random.PRNGKey(1), cfg, stages=2)

    leaves = []

    def walk(p):
        if isinstance(p, blockamc.LeafInvPlan):
            leaves.append(p.pair.shape)
        else:
            walk(p.inv1)
            walk(p.inv4s)
            for row in p.mvm2 + p.mvm3:
                for t in row:
                    leaves.append(t.shape)

    walk(plan.root)
    return {"n_arrays": len(leaves),
            "all_64": all(s == (64, 64) for s in leaves)}


def main():
    rows = run()
    st = structure_check()
    save_json("fig8_twostage", {"rows": rows, "structure": st})
    r256 = next(r for r in rows if r["n"] == 256)
    csv_row("fig8_twostage_n256", 0.0,
            f"two_stage={r256['two_stage_median']:.3f};"
            f"orig={r256['orig_median']:.3f};arrays={st['n_arrays']};"
            f"all64={st['all_64']}")
    return rows


if __name__ == "__main__":
    main()

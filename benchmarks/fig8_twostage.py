"""Paper Fig. 8: two-stage BlockAMC (256x256 -> 16 arrays of 64x64).

(a/b) stage-resolved INV accuracy, (c) final solutions, (d) error vs size
for the two-stage solver vs original AMC, all with device variation.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (N_SIMS_PAPER, csv_row, mc_errors, save_json)
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig

SIZES = (64, 128, 256, 512)


def run(n_sims=None):
    # resolve at call time so run.py's fast-mode overrides stick
    n_sims = N_SIMS_PAPER if n_sims is None else n_sims
    rows = []
    for n in SIZES:
        cfg = AnalogConfig(array_size=max(n // 4, 4),
                           nonideal=NonidealConfig(sigma=0.05))
        e2 = mc_errors("wishart", n, cfg, "blockamc", n_sims, stages=2)
        eo = mc_errors("wishart", n, cfg, "original", n_sims)
        rows.append({"n": n,
                     "two_stage_median": float(np.median(e2)),
                     "orig_median": float(np.median(eo))})
    return rows


def structure_check():
    """16 x (64x64) leaves for n=256, stages=2 (paper's partitioning)."""
    from repro.core import blockamc
    from repro.data.matrices import wishart
    a = wishart(jax.random.PRNGKey(0), 256)
    cfg = AnalogConfig(array_size=64)
    plan = blockamc.build_plan(a, jax.random.PRNGKey(1), cfg, stages=2)

    leaves = []

    def walk(p):
        if isinstance(p, blockamc.LeafInvPlan):
            leaves.append(p.pair.shape)
        else:
            walk(p.inv1)
            walk(p.inv4s)
            for row in p.mvm2 + p.mvm3:
                for t in row:
                    leaves.append(t.shape)

    walk(plan.root)
    return {"n_arrays": len(leaves),
            "all_64": all(s == (64, 64) for s in leaves)}


def main():
    rows = run()
    st = structure_check()
    save_json("fig8_twostage", {"rows": rows, "structure": st})
    # headline row: the paper's Fig. 8 n=256 config when present (paper and
    # fast mode), else the largest size run (--smoke); the structure fields
    # describe the 256x256 partitioning, so only the n=256 row carries them
    top = next((r for r in rows if r["n"] == 256),
               max(rows, key=lambda r: r["n"]))
    derived = (f"two_stage={top['two_stage_median']:.3f};"
               f"orig={top['orig_median']:.3f}")
    if top["n"] == 256:
        derived += f";arrays={st['n_arrays']};all64={st['all_64']}"
    csv_row(f"fig8_twostage_n{top['n']}", 0.0, derived)
    return rows


if __name__ == "__main__":
    main()

"""Hybrid analog-digital benchmark: the refinement loop made quantitative.

Sweeps condition number x device variation x wire model and records, per
combination, the iterations-to-1e-10 (and convergence flags) of

  * unpreconditioned digital CG (the all-digital baseline),
  * seed-only refinement (analog seed, plain CG - the robust serving mode),
  * BlockAMC-preconditioned CG and GMRES (the programmed cascade applied
    inside the iteration),

plus wall-clock for the first two (stalled preconditioned runs burn full
fuel, so per-row precond timings would be noise; the acceptance headline
carries the preconditioned wall-clock instead), into
`artifacts/bench/hybrid.json` - with the headline (cond ~ 1e4,
write-verified programming) asserted by tests/test_hybrid_krylov.py.
The sweep shows the whole regime map: preconditioning wins big while
sigma x cond is small, goes indefinite beyond it (PCG stalls, GMRES
degrades gracefully), and seed-only refinement always converges.

Digital refinement runs in float64 (`jax.experimental.enable_x64`); the
programmed cascade is the same noisy analog model as everywhere else.
"""
from __future__ import annotations

import argparse
import os
import sys
from functools import partial

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from benchmarks.common import csv_row, save_json, timed
from repro import hybrid
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import random_rhs, wishart_with_cond
from repro.hybrid import AnalogPreconditioner, matvec_from_dense, pcg

SMOKE = False
N = 96
N_PAPER = 256
TOL = 1e-10
MAXITER = 20000


@partial(jax.jit, static_argnames=("tol", "maxiter"))
def _plain_cg(a, b, tol, maxiter):
    return pcg(matvec_from_dense(a), b, tol=tol, maxiter=maxiter)


def _refined(a, b, precond, method, use_precond, maxiter=MAXITER):
    return hybrid.solve_refined(a, b, precond, method=method, tol=TOL,
                                maxiter=maxiter, restart=32,
                                use_precond=use_precond)


def _sweep(n, conds, sigmas, wires, keys):
    ka, kb, kn = keys
    rows = []
    for cond in conds:
        a = wishart_with_cond(ka, n, cond, dtype=jnp.float64)
        b = random_rhs(kb, n).astype(jnp.float64)
        plain = _plain_cg(a, b, TOL, MAXITER)
        wall_plain = timed(lambda: jax.block_until_ready(
            _plain_cg(a, b, TOL, MAXITER)), iters=3)
        for sigma in sigmas:
            for r_wire in wires:
                cfg = AnalogConfig(
                    array_size=n // 2,
                    nonideal=NonidealConfig(sigma=sigma, r_wire=r_wire))
                precond = AnalogPreconditioner.program(a, kn, cfg, stages=1)
                seed = precond(b)
                seed_res = float(jnp.linalg.norm(b - a @ seed)
                                 / jnp.linalg.norm(b))
                _, seeded = _refined(a, b, precond, "cg", False)
                _, pcg_res = _refined(a, b, precond, "cg", True)
                _, gm_res = _refined(a, b, precond, "gmres", True)
                wall_seeded = timed(lambda: jax.block_until_ready(
                    _refined(a, b, precond, "cg", False)), iters=3)
                rows.append({
                    "cond": cond, "sigma": sigma, "r_wire": r_wire,
                    "seed_res": seed_res,
                    "iters_plain_cg": int(plain.iters),
                    "conv_plain_cg": bool(plain.converged),
                    "wall_us_plain_cg": wall_plain,
                    "iters_seed_cg": int(seeded.iters),
                    "conv_seed_cg": bool(seeded.converged),
                    "wall_us_seed_cg": wall_seeded,
                    "iters_precond_cg": int(pcg_res.iters),
                    "conv_precond_cg": bool(pcg_res.converged),
                    "iters_precond_gmres": int(gm_res.iters),
                    "conv_precond_gmres": bool(gm_res.converged),
                })
    return rows


def _headline(keys):
    """The acceptance configuration (mirrors test_hybrid_krylov.py):
    cond ~ 1e4, n=64, write-verified programming."""
    ka, kb, kn = keys
    n = 64
    a = wishart_with_cond(ka, n, 1e4, dtype=jnp.float64)
    b = random_rhs(kb, n).astype(jnp.float64)
    plain = _plain_cg(a, b, TOL, MAXITER)
    cfg_cg = AnalogConfig(array_size=n // 2, opa_gain=1e5)
    m_cg = AnalogPreconditioner.program(a, kn, cfg_cg, stages=1)
    _, res_cg = _refined(a, b, m_cg, "cg", True, maxiter=4000)
    cfg_gm = AnalogConfig(array_size=n // 2, nonideal=NonidealConfig(
        sigma=1e-4, r_wire=1.0, compensate_wire=True))
    m_gm = AnalogPreconditioner.program(a, kn, cfg_gm, stages=1)
    _, res_gm = _refined(a, b, m_gm, "gmres", True, maxiter=4000)
    wall_plain = timed(lambda: jax.block_until_ready(
        _plain_cg(a, b, TOL, MAXITER)), iters=3)
    wall_gm = timed(lambda: jax.block_until_ready(
        _refined(a, b, m_gm, "gmres", True, maxiter=4000)), iters=3)
    return {
        "n": n, "cond": 1e4, "tol": TOL,
        "iters_plain_cg": int(plain.iters),
        "iters_precond_cg": int(res_cg.iters),
        "conv_precond_cg": bool(res_cg.converged),
        "precond_cg_cfg": {"sigma": 0.0, "opa_gain": 1e5},
        "iters_precond_gmres": int(res_gm.iters),
        "conv_precond_gmres": bool(res_gm.converged),
        "precond_gmres_cfg": {"sigma": 1e-4, "r_wire": 1.0,
                              "compensate_wire": True},
        "wall_us_plain_cg": wall_plain,
        "wall_us_precond_gmres": wall_gm,
        "speedup_iters_gmres": int(plain.iters) / max(int(res_gm.iters), 1),
    }


def run():
    n = 48 if SMOKE else N
    conds = (1e1, 1e3) if SMOKE else (1e1, 1e3, 1e5)
    sigmas = (0.0, 0.05) if SMOKE else (0.0, 0.02, 0.05)
    wires = (0.0,) if SMOKE else (0.0, 1.0)
    with enable_x64():
        keys = jax.random.split(jax.random.PRNGKey(0), 3)
        rows = _sweep(n, conds, sigmas, wires, keys)
        headline = _headline(keys)
    return {"n": n, "tol": TOL, "smoke": SMOKE, "rows": rows,
            "headline": headline}


def main():
    payload = run()
    save_json("hybrid", payload)
    h = payload["headline"]
    csv_row("hybrid_headline_cond1e4", h["wall_us_precond_gmres"],
            f"gmres={h['iters_precond_gmres']};pcg={h['iters_precond_cg']};"
            f"plain={h['iters_plain_cg']};"
            f"speedup={h['speedup_iters_gmres']:.1f}x")
    for r in payload["rows"]:
        csv_row(
            f"hybrid_cond{r['cond']:.0e}_s{r['sigma']}_w{r['r_wire']}",
            r["wall_us_seed_cg"],
            f"plain={r['iters_plain_cg']};seed={r['iters_seed_cg']};"
            f"pcg={r['iters_precond_cg']}({'+' if r['conv_precond_cg'] else '-'});"
            f"gmres={r['iters_precond_gmres']}"
            f"({'+' if r['conv_precond_gmres'] else '-'})")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke mode: tiny grid, <1 min")
    ap.add_argument("--paper", action="store_true",
                    help="full 256-size protocol")
    args = ap.parse_args()
    if args.smoke:
        SMOKE = True
    if args.paper:
        N = N_PAPER
    main()

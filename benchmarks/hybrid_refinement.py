"""Hybrid analog-digital benchmark: AMC seed value for digital iteration.

The paper's positioning statement made quantitative: how many CG /
Richardson iterations to 1e-6 residual does a (noisy) BlockAMC seed save
vs a zero seed, as a function of the non-ideality level?
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, matrix_of, save_json
from repro.core import blockamc, hybrid
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import random_rhs

N = 256


def run():
    ka, kb, kn = jax.random.split(jax.random.PRNGKey(0), 3)
    a = matrix_of("wishart", ka, N)
    b = random_rhs(kb, N)
    rows = []
    zeros = jnp.zeros_like(b)
    for sigma in (0.0, 0.02, 0.05, 0.1):
        cfg = AnalogConfig(array_size=N // 2,
                           nonideal=NonidealConfig(sigma=sigma))
        x_seed = blockamc.solve(a, b, kn, cfg, stages=1)
        row = {"sigma": sigma}
        for method in ("cg", "richardson"):
            _, it_seed = hybrid.iterations_to_tol(a, b, x_seed, tol=1e-6,
                                                  method=method,
                                                  max_iters=20000)
            _, it_zero = hybrid.iterations_to_tol(a, b, zeros, tol=1e-6,
                                                  method=method,
                                                  max_iters=20000)
            row[f"{method}_seed"] = int(it_seed)
            row[f"{method}_zero"] = int(it_zero)
        rows.append(row)
    return rows


def main():
    rows = run()
    save_json("hybrid_refinement", {"rows": rows})
    for r in rows:
        csv_row(f"hybrid_sigma{r['sigma']}", 0.0,
                f"cg={r['cg_seed']}/{r['cg_zero']};"
                f"rich={r['richardson_seed']}/{r['richardson_zero']}")
    # honest beyond-paper observation recorded in EXPERIMENTS.md: a noisy
    # seed helps slow stationary methods (Richardson) roughly in proportion
    # to log(seed error), but barely moves Krylov methods (CG) on
    # well-conditioned systems.
    return rows


if __name__ == "__main__":
    main()

"""Shared benchmark helpers: Monte-Carlo error sweeps + CSV/JSON reporting."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blockamc
from repro.core.analog import AnalogConfig
from repro.core.metrics import relative_error
from repro.data.matrices import random_rhs, toeplitz, wishart

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

SIZES_PAPER = (8, 16, 32, 64, 128, 256, 512)
N_SIMS_PAPER = 40                       # "40 random simulations" (Section IV)


def save_json(name: str, payload: Dict) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def csv_row(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def matrix_of(family: str, key, n: int):
    return wishart(key, n) if family == "wishart" else toeplitz(key, n)


def mc_errors(family: str, n: int, cfg: AnalogConfig, solver: str,
              n_sims: int = N_SIMS_PAPER, stages=None, seed: int = 0
              ) -> np.ndarray:
    """Relative errors over `n_sims` independent device-noise draws."""
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    a = matrix_of(family, ka, n)
    b = random_rhs(kb, n)
    x_ref = jnp.linalg.solve(a, b)
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), n_sims)

    if solver == "original":
        fn = lambda k: blockamc.solve_original(a, b, k, cfg)
    else:
        fn = lambda k: blockamc.solve(a, b, k, cfg, stages=stages)
    xs = jax.lax.map(fn, keys)          # sequential map: modest memory
    errs = jax.vmap(lambda x: relative_error(x_ref, x))(xs)
    return np.asarray(errs)


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-clock microseconds per call (CPU; documentation only)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))

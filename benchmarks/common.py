"""Shared benchmark helpers: Monte-Carlo error sweeps + CSV/JSON reporting."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blockamc
from repro.core.analog import AnalogConfig
from repro.core.metrics import relative_error
from repro.data.matrices import random_rhs, toeplitz, wishart

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

SIZES_PAPER = (8, 16, 32, 64, 128, 256, 512)
N_SIMS_PAPER = 40                       # "40 random simulations" (Section IV)


def save_json(name: str, payload: Dict) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def csv_row(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def matrix_of(family: str, key, n: int):
    return wishart(key, n) if family == "wishart" else toeplitz(key, n)


def _mc_problem(family: str, n: int, n_sims: int, seed: int):
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    a = matrix_of(family, ka, n)
    b = random_rhs(kb, n)
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), n_sims)
    return a, b, jnp.linalg.solve(a, b), keys


def mc_solutions(a, b, keys, cfg: AnalogConfig, solver: str, stages=None):
    """All Monte-Carlo solutions in one jit via the flat batched executor."""
    if solver == "original":
        return blockamc.solve_original_batched(a, b, keys, cfg)
    return blockamc.solve_batched(a, b, keys, cfg, stages=stages)


def mc_solutions_recursive(a, b, keys, cfg: AnalogConfig, solver: str,
                           stages=None):
    """The per-seed recursive tree walk (pre-flat-executor reference path).

    Kept for the kernel_bench recursive-vs-batched comparison and as the
    executor-equivalence oracle; the default Monte-Carlo path is
    `mc_solutions`.
    """
    if solver == "original":
        fn = lambda k: blockamc.solve_original(a, b, k, cfg)
    else:
        fn = lambda k: blockamc.solve(a, b, k, cfg, stages=stages)
    return jax.lax.map(fn, keys)        # sequential map: modest memory


def mc_errors(family: str, n: int, cfg: AnalogConfig, solver: str,
              n_sims=None, stages=None, seed: int = 0,
              batched: bool = True) -> np.ndarray:
    """Relative errors over `n_sims` independent device-noise draws.

    n_sims=None reads N_SIMS_PAPER at call time, so run.py's fast/smoke
    overrides of the module global take effect.  batched=True (default)
    runs every seed in one level-scheduled batched solve; batched=False
    keeps the sequential recursive walk per seed.
    """
    n_sims = N_SIMS_PAPER if n_sims is None else n_sims
    a, b, x_ref, keys = _mc_problem(family, n, n_sims, seed)
    run = mc_solutions if batched else mc_solutions_recursive
    xs = run(a, b, keys, cfg, solver, stages=stages)
    errs = jax.vmap(lambda x: relative_error(x_ref, x))(xs)
    return np.asarray(errs)


# Shared timing protocol: warmup calls (compile + cache warm) followed by a
# median over N measured calls.  The defaults are overridable per run via
# run.py --bench-warmup/--bench-iters (shared CI runners are noisy; the
# nightly diff gate depends on these numbers being stable).
TIMED_WARMUP = 3
TIMED_ITERS = 9


def timed(fn: Callable, *args, warmup: int = None, iters: int = None) -> float:
    """Median wall-clock microseconds per call after warmup (CPU)."""
    warmup = TIMED_WARMUP if warmup is None else warmup
    iters = TIMED_ITERS if iters is None else iters
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))

"""Generate the full roofline table (ROOFLINE.md) from dry-run artifacts."""
from __future__ import annotations

import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def rows_for(directory: str, mesh: str):
    rows = []
    for p in sorted(glob.glob(os.path.join(directory, f"*__{mesh}.json"))):
        r = json.load(open(p))
        if "skipped" in r:
            rows.append((r["arch"], r["shape"], "SKIP (sub-quadratic rule)",
                         "", "", "", "", ""))
            continue
        if "error" in r:
            rows.append((r["arch"], r["shape"], "ERROR", "", "", "", "", ""))
            continue
        t = r["roofline"]
        dom = r["dominant"].replace("_s", "")
        frac = (t["compute_s"] / max(t[r["dominant"]], 1e-12))
        rows.append((
            r["arch"], r["shape"], dom,
            f"{t['compute_s']:.4g}", f"{t['memory_s']:.4g}",
            f"{t['collective_s']:.4g}",
            f"{r.get('useful_flop_ratio') or 0:.2f}",
            f"{frac:.3f}"))
    return rows


def table(rows):
    head = ("| arch | shape | dominant | compute s | memory s | "
            "collective s | useful | roofline frac |\n"
            "|---|---|---|---|---|---|---|---|\n")
    return head + "\n".join(
        "| " + " | ".join(str(c) for c in r) + " |" for r in rows)


def main():
    out = ["# Roofline tables (generated)\n"]
    out.append("\n## Single-pod 16x16 — optimized (current framework)\n")
    out.append(table(rows_for(os.path.join(ROOT, "dryrun"), "16x16")))
    out.append("\n\n## Multi-pod 2x16x16 — optimized\n")
    out.append(table(rows_for(os.path.join(ROOT, "dryrun"), "2x16x16")))
    base = os.path.join(ROOT, "dryrun_baseline_pre_hillclimb")
    if os.path.isdir(base):
        out.append("\n\n## Single-pod 16x16 — paper-faithful baseline "
                   "(pre-hillclimb)\n")
        out.append(table(rows_for(base, "16x16")))
    text = "".join(out) + "\n"
    path = os.path.join(os.path.dirname(__file__), "..", "ROOFLINE.md")
    with open(path, "w") as f:
        f.write(text)
    print(text)


if __name__ == "__main__":
    main()

"""Example: the distributed BlockAMC solver service + Pallas MVM kernel.

    PYTHONPATH=src python examples/solve_linear_system.py

1. Solves a 1024x1024 system with the vectorised tile solver (the code path
   that shards over the production mesh in the dry-run).
2. Programs a 256x256 matrix once and streams a batch of right-hand sides
   through the `ProgrammedSolver` multi-RHS path (program-once/solve-many).
3. Refines the noisy analog batch to digital precision with the hybrid
   Krylov subsystem (analog seed -> batched CG, repro.hybrid).
4. Runs the analog crossbar MVM through the Pallas kernel (interpret mode on
   CPU) and checks it against both the jnp oracle and the circuit model.
5. Prints the area/energy verdict for the equivalent hardware.
"""
import jax
import jax.numpy as jnp

from repro.core import area_energy, blockamc, distributed
from repro.core.analog import AnalogConfig, map_tiled_vec
from repro.core.metrics import relative_error
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import random_rhs, wishart
from repro.hybrid import AnalogPreconditioner, solve_refined
from repro.kernels import ops, ref


def main():
    ka, kb, kn = jax.random.split(jax.random.PRNGKey(0), 3)
    n = 1024
    a = wishart(ka, n)
    b = random_rhs(kb, n)
    x_true = jnp.linalg.solve(a, b)
    for sigma in (0.0, 0.01, 0.05):
        cfg = AnalogConfig(array_size=128,
                           nonideal=NonidealConfig(sigma=sigma))
        x = distributed.solve_distributed(a, b, kn, cfg, stages=3)
        err = float(relative_error(x_true, x))
        print(f"distributed BlockAMC n={n} stages=3 sigma={sigma}: "
              f"rel err {err:.2e}")
    cfg = AnalogConfig(array_size=128, nonideal=NonidealConfig(sigma=0.05))

    # Program-once / solve-many: one finalized 256x256 two-stage solver
    # answers a whole batch of right-hand sides at marginal cost.
    cfg64 = AnalogConfig(array_size=64, nonideal=NonidealConfig(sigma=0.05))
    a256 = a[:256, :256]
    solver = blockamc.ProgrammedSolver.program(a256, kn, cfg64, stages=2)
    bs = jax.random.normal(kb, (256, 16))
    xs = solver.solve_many(bs)
    xs_ref = jnp.linalg.solve(a256, bs)
    errs = jax.vmap(relative_error, in_axes=1)(xs_ref, xs)
    print(f"programmed 256x256 two-stage solver, 16 streamed rhs: "
          f"median rel err {float(jnp.median(errs)):.3f} "
          f"({solver.num_arrays} arrays programmed once)")

    # Hybrid refinement: the same programmed arrays seed a batched digital
    # CG that polishes all 16 right-hand sides to f32 precision in one call
    precond = AnalogPreconditioner.from_solver(solver)
    xs_refined, info = solve_refined(a256, bs, precond, method="cg",
                                     tol=1e-6, maxiter=300,
                                     use_precond=False)
    errs_ref = jax.vmap(relative_error, in_axes=1)(xs_ref, xs_refined)
    print(f"hybrid refined (analog seed + batched CG): median rel err "
          f"{float(jnp.median(errs_ref)):.2e}, median iters "
          f"{int(jnp.median(info.iters))}, all converged: "
          f"{bool(info.converged.all())}")

    # Pallas crossbar MVM on one mapped tile grid (canonical home of the
    # stacked-tile mapping is core/analog.py since the flat-executor PR)
    scale = 1.0 / jnp.max(jnp.abs(a))
    grid = map_tiled_vec(a256, kn, cfg, scale)
    gpos = grid.gpos.reshape(-1, 256)[:256]
    gneg = grid.gneg.reshape(-1, 256)[:256]
    v = random_rhs(kb, 256)[None, :]
    out_kernel = ops.crossbar_mvm(v, gpos, gneg, g0=cfg.g0,
                                  dac_bits=8, adc_bits=8)
    out_ref = ref.crossbar_mvm_ref(v, gpos, gneg, g0=cfg.g0,
                                   dac_bits=8, adc_bits=8)
    dev = float(jnp.max(jnp.abs(out_kernel - out_ref)))
    print(f"pallas crossbar_mvm vs oracle: max dev {dev:.2e}")

    sav = area_energy.savings(area_energy.report())
    print(f"hardware verdict (512x512): one-stage saves "
          f"{sav['area']['one_stage']:.1%} area / "
          f"{sav['power']['one_stage']:.1%} power vs a monolithic AMC")


if __name__ == "__main__":
    main()

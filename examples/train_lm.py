"""End-to-end driver: train a ~100M-class LM for a few hundred steps on CPU.

    PYTHONPATH=src python examples/train_lm.py [--arch glm4-9b] [--steps 200]

Uses the real framework stack - config -> data pipeline -> train_step with
remat + microbatching -> AdamW -> async checkpointing -> watchdog - on a
host-scale model of the chosen architecture family.  Loss on the synthetic
copy-structured corpus should drop clearly within the first hundred steps.
"""
import argparse
import dataclasses
import logging

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(message)s")

    base = get_config(args.arch)
    # ~100M-parameter family member (framework-scale config, CPU-trainable)
    cfg = dataclasses.replace(
        base,
        n_layers=6 if not base.layer_pattern else 2 * len(base.layer_pattern),
        d_model=512, d_ff=1408 if base.d_ff else 0,
        n_heads=8 if base.n_heads else 0,
        kv_heads=min(base.kv_heads, 4) if base.kv_heads else 0,
        head_dim=64, vocab=8192,
        n_experts=min(base.n_experts, 8),
        local_window=128,
        lru_width=512 if base.lru_width else None,
        param_dtype="float32", compute_dtype="float32",
    )
    run = RunConfig(model=cfg, mode="train", seq_len=256, global_batch=8,
                    microbatch=4, remat="dots", learning_rate=1e-3)
    trainer = Trainer(cfg, run, ckpt_dir=args.ckpt_dir, ckpt_every=50,
                      log_every=10)
    hist = trainer.run(args.steps)
    print(f"loss: {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f} "
          f"({args.steps} steps, arch family {args.arch})")


if __name__ == "__main__":
    main()

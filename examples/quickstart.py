"""Quickstart: solve a linear system with BlockAMC in 30 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a 256x256 Wishart system, solves it with the paper's one-stage and
two-stage BlockAMC under realistic non-idealities (5% conductance noise,
1 ohm wire segments), and refines the analog seed digitally - the full
hybrid flow the paper positions AMC for.
"""
import jax
import jax.numpy as jnp

from repro.core import blockamc, hybrid
from repro.core.analog import AnalogConfig
from repro.core.metrics import relative_error
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import random_rhs, wishart


def main():
    key_a, key_b, key_noise = jax.random.split(jax.random.PRNGKey(0), 3)
    a = wishart(key_a, 256)
    b = random_rhs(key_b, 256)
    x_true = jnp.linalg.solve(a, b)

    for sigma in (0.01, 0.05):
        cfg = AnalogConfig(
            array_size=128,                      # max physical RRAM array
            nonideal=NonidealConfig(sigma=sigma,  # conductance noise (of G0)
                                    r_wire=1.0),  # 1 ohm wire segments
        )
        for stages, label in ((1, "one-stage"), (2, "two-stage")):
            x_analog = blockamc.solve(a, b, key_noise, cfg, stages=stages)
            err = float(relative_error(x_true, x_analog))
            x_refined, iters = hybrid.iterations_to_tol(
                a, b, x_analog, tol=1e-6, method="richardson",
                max_iters=20000)
            final = float(relative_error(x_true, x_refined))
            print(f"sigma={sigma:.2f} {label:10s}: analog seed err {err:.3f}"
                  f" -> refined {final:.2e} in {int(iters)} Richardson iters")

    # The paper's 40-seed Monte-Carlo in one batched call: the flat
    # level-scheduled executor runs all seeds' cascades as a few stacked ops.
    cfg = AnalogConfig(array_size=64, nonideal=NonidealConfig(sigma=0.05))
    keys = jax.random.split(key_noise, 40)
    xs = blockamc.solve_batched(a, b, keys, cfg, stages=2)
    errs = jax.vmap(lambda x: relative_error(x_true, x))(xs)
    print(f"40-seed two-stage Monte-Carlo (batched): median err "
          f"{float(jnp.median(errs)):.3f}")

    # Program-once / solve-many: the AMC cost model.  Programming the arrays
    # (partitioning, Schur complements, mapping, operator finalization) is
    # paid once; each streamed rhs then costs one pass of batched lu_solves
    # and stacked matmuls against the precomputed operators.
    key_prog, key_stream = jax.random.split(jax.random.fold_in(key_noise, 1))
    solver = blockamc.ProgrammedSolver.program(a, key_prog, cfg, stages=2)
    bs = jax.random.normal(key_stream, (256, 8))
    xs_stream = solver.solve_many(bs)
    err0 = float(relative_error(jnp.linalg.solve(a, bs[:, 0]),
                                xs_stream[:, 0]))
    print(f"programmed solver: {solver.num_arrays} arrays, 8 streamed rhs, "
          f"first-column err {err0:.3f}")

    _, iters_zero = hybrid.iterations_to_tol(
        a, b, jnp.zeros_like(b), tol=1e-6, method="richardson",
        max_iters=20000)
    print(f"zero seed : {int(iters_zero)} Richardson iterations")
    print("(the analog head start scales with seed accuracy; at high noise "
          "the seed adds little - see EXPERIMENTS.md)")


if __name__ == "__main__":
    main()

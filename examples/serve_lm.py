"""Serve a small model with batched requests through the generation engine.

    PYTHONPATH=src python examples/serve_lm.py [--arch recurrentgemma-2b]

Demonstrates prefill -> batched greedy decode with the family-correct cache
(KV ring buffers for local attention, recurrent states for RG-LRU/SSD).
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.launch.train import host_scale_config
from repro.models import transformer as tr
from repro.models.lm_engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=64)
    args = ap.parse_args()

    cfg = host_scale_config(get_config(args.arch))
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params,
                    max_len=args.prompt_len + args.gen_len + 1)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab)

    t0 = time.perf_counter()
    out = engine.generate(prompts, args.gen_len)
    dt = time.perf_counter() - t0
    print(f"arch family     : {args.arch} (host-scale)")
    print(f"batch x gen     : {args.batch} x {args.gen_len}")
    print(f"throughput      : {args.batch * args.gen_len / dt:.1f} tok/s (CPU)")
    print(f"first sequences : {out[:2, :12]}")


if __name__ == "__main__":
    main()
